"""Frame-multiplexed pipeline tests (paper Sec. III-B, Fig. 4) on the
``VisualSystem`` session API: schedule equivalence, quad-frame pair
coverage, degenerate sequence lengths, and the analytic Fig. 4
timeline."""

import jax
import numpy as np
import pytest

from repro.core import (ORBConfig, PipelineConfig, RigConfig, VisualSystem,
                        pipeline_schedule)
from repro.data import scenes


def _sequence(t=3):
    cfg = scenes.SceneConfig(height=96, width=128, n_points=60, seed=4)
    frames, poses, intr = scenes.render_sequence(cfg, t)
    ocfg = ORBConfig(height=96, width=128, max_features=48, n_levels=1,
                     max_disparity=48)
    return frames, ocfg, intr


def _system(ocfg, intr, schedule="sequential"):
    return VisualSystem(RigConfig.quad(intr),
                        PipelineConfig(orb=ocfg, schedule=schedule))


def test_pipelined_equals_reference_schedule():
    """Fig. 4 pipelining is a schedule change, not a math change: the
    pipelined sequence must produce identical per-frame outputs."""
    frames, ocfg, intr = _sequence(3)
    a = _system(ocfg, intr, "sequential").run(frames)
    b = _system(ocfg, intr, "pipelined").run(frames)
    for fa, fb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        fa, fb = np.asarray(fa), np.asarray(fb)
        if np.issubdtype(fa.dtype, np.floating):
            # XLA fuses the two schedules differently -> last-ulp drift
            np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)
        else:
            np.testing.assert_array_equal(fa, fb)


def test_pipelined_single_frame_sequence():
    """T == 1 degenerates to prologue + drain (an empty scan) and must
    equal the sequential schedule — the old implementation's bubble
    accounting was only exercised for T >= 2."""
    frames, ocfg, intr = _sequence(1)
    a = _system(ocfg, intr, "sequential").run(frames)
    b = _system(ocfg, intr, "pipelined").run(frames)
    assert b.matches.valid.shape[0] == 1
    for fa, fb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                   rtol=1e-4, atol=1e-4)


def test_empty_sequence_raises_clear_error():
    """T == 0 must fail eagerly with a clear ValueError (the old path
    died on a bare in-trace assert), on both schedules."""
    frames, ocfg, intr = _sequence(1)
    empty = frames[:0]
    for schedule in ("sequential", "pipelined"):
        with pytest.raises(ValueError, match="empty sequence"):
            _system(ocfg, intr, schedule).run(empty)


def test_quad_frame_processes_both_pairs():
    frames, ocfg, intr = _sequence(1)
    out = _system(ocfg, intr).process_frame(frames[0])
    assert out.matches.valid.shape[0] == 2      # two stereo pairs
    v = np.asarray(out.depth.valid)
    assert v.shape[0] == 2
    assert v[0].sum() > 0 and v[1].sum() > 0    # 360-degree coverage: both
                                                # hemispheres yield depth


def test_frame_shape_validation_errors():
    frames, ocfg, intr = _sequence(1)
    vs = _system(ocfg, intr)
    with pytest.raises(ValueError, match="rank-3"):
        vs.process_frame(frames)                # (T, 4, H, W): too many dims
    with pytest.raises(ValueError, match="4 cameras"):
        vs.process_frame(frames[0, :2])         # camera axis mismatch
    with pytest.raises(ValueError, match="does not match"):
        vs.process_frame(frames[0, :, :64, :])  # H/W vs ORBConfig


def test_pipeline_schedule_steady_state_period():
    """Paper profiling: FE=7.28 ms, FM=14.59 ms at 640x480.  The frame-
    multiplexed pipeline's steady-state period is max(2*FE, FM) — the
    rationale for sharing one FE between two channels (2*7.28 ~ 14.59)."""
    sched = pipeline_schedule(50, t_fe_ms=7.28, t_fm_ms=14.59)
    assert abs(sched["steady_period_ms"] - 14.59) < 1e-9
    # makespan ~ prologue + N * period, far below the serial schedule
    serial = 50 * sched["serial_period_ms"]
    assert sched["makespan_ms"] < 0.55 * serial
    # FE is never the bottleneck: FE(n+1) always starts before FM(n) ends
    fe, fm = sched["fe_start"], sched["fm_end"]
    assert all(fe[n + 1] < fm[n] for n in range(49))


def test_pipeline_schedule_fe_bound_regime():
    """If FE were slower than FM/2 the period would flip to 2*FE."""
    sched = pipeline_schedule(10, t_fe_ms=10.0, t_fm_ms=12.0)
    assert sched["steady_period_ms"] == 20.0
