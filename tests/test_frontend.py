"""Frame-multiplexed pipeline tests (paper Sec. III-B, Fig. 4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CameraIntrinsics, ORBConfig, pipeline_schedule,
                        process_quad_frame, run_sequence,
                        run_sequence_pipelined)
from repro.data import scenes


def _sequence(t=3):
    cfg = scenes.SceneConfig(height=96, width=128, n_points=60, seed=4)
    frames, poses, intr = scenes.render_sequence(cfg, t)
    ocfg = ORBConfig(height=96, width=128, max_features=48, n_levels=1,
                     max_disparity=48)
    return frames, ocfg, intr


def test_pipelined_equals_reference_schedule():
    """Fig. 4 pipelining is a schedule change, not a math change: the
    pipelined sequence must produce identical per-frame outputs."""
    frames, ocfg, intr = _sequence(3)
    a = run_sequence(frames, ocfg, intr)
    b = run_sequence_pipelined(frames, ocfg, intr)
    for fa, fb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        fa, fb = np.asarray(fa), np.asarray(fb)
        if np.issubdtype(fa.dtype, np.floating):
            # XLA fuses the two schedules differently -> last-ulp drift
            np.testing.assert_allclose(fa, fb, rtol=1e-4, atol=1e-4)
        else:
            np.testing.assert_array_equal(fa, fb)


def test_quad_frame_processes_both_pairs():
    frames, ocfg, intr = _sequence(1)
    out = process_quad_frame(frames[0], ocfg, intr)
    assert out.matches.valid.shape[0] == 2      # two stereo pairs
    v = np.asarray(out.depth.valid)
    assert v.shape[0] == 2
    assert v[0].sum() > 0 and v[1].sum() > 0    # 360-degree coverage: both
                                                # hemispheres yield depth


def test_pipeline_schedule_steady_state_period():
    """Paper profiling: FE=7.28 ms, FM=14.59 ms at 640x480.  The frame-
    multiplexed pipeline's steady-state period is max(2*FE, FM) — the
    rationale for sharing one FE between two channels (2*7.28 ~ 14.59)."""
    sched = pipeline_schedule(50, t_fe_ms=7.28, t_fm_ms=14.59)
    assert abs(sched["steady_period_ms"] - 14.59) < 1e-9
    # makespan ~ prologue + N * period, far below the serial schedule
    serial = 50 * sched["serial_period_ms"]
    assert sched["makespan_ms"] < 0.55 * serial
    # FE is never the bottleneck: FE(n+1) always starts before FM(n) ends
    fe, fm = sched["fe_start"], sched["fm_end"]
    assert all(fe[n + 1] < fm[n] for n in range(49))


def test_pipeline_schedule_fe_bound_regime():
    """If FE were slower than FM/2 the period would flip to 2*FE."""
    sched = pipeline_schedule(10, t_fe_ms=10.0, t_fm_ms=12.0)
    assert sched["steady_period_ms"] == 20.0
