"""Launch-layer tests: the dry-run cell builder end-to-end on the host
mesh (reduced configs), and the loop-aware HLO statistics parser
against a program with known FLOPs/collectives/trip counts."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as Ps

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ShapeCell
from repro.launch import hlo_stats
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_host_mesh


SMOKE_CELLS = [
    ShapeCell("train_small", "train", 64, 2),
    ShapeCell("prefill_small", "prefill", 64, 2),
    ShapeCell("decode_small", "decode", 64, 2),
]


@pytest.mark.parametrize("aid", ARCH_IDS)
@pytest.mark.parametrize("cell", SMOKE_CELLS, ids=lambda c: c.name)
def test_build_cell_lowers_and_compiles(aid, cell):
    """The same builder the 512-chip dry-run uses, on the host mesh
    with the reduced config — lower + compile must succeed and report
    sane statistics for every (arch x kind)."""
    cfg = get_smoke_config(aid)
    mesh = make_host_mesh()
    built = specs_mod.build_cell(cfg, cell, mesh)
    kwargs = dict(in_shardings=built.in_shardings)
    if built.out_shardings is not None:
        kwargs["out_shardings"] = built.out_shardings
    compiled = jax.jit(built.step_fn, **kwargs).lower(
        *built.arg_specs).compile()
    st = hlo_stats.analyze(compiled.as_text())
    assert st.flops > 0
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0


def test_hlo_stats_scanned_matmul_exact():
    """Known program: L=5 scanned (B,D)x(D,D) matmuls, weights
    model-sharded on a (1,1) mesh -> per-device flops = 2*L*B*D*D."""
    mesh = make_host_mesh()
    L, B, D = 5, 8, 16

    def step(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    compiled = jax.jit(step).lower(ws, x).compile()
    st = hlo_stats.analyze(compiled.as_text())
    assert st.flops == 2 * L * B * D * D, st.flops
    assert list(st.while_trips.values()) == [L]


def test_hlo_stats_counts_collectives():
    """all-gather of a model-sharded tensor must appear with its
    gathered result bytes."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_host_mesh()
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)

    def f(x):
        y = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, Ps("data", "model")))
        return jax.lax.with_sharding_constraint(
            y * 2.0, NamedSharding(mesh, Ps()))

    compiled = jax.jit(f).lower(x).compile()
    st = hlo_stats.analyze(compiled.as_text())
    # on a 1x1 mesh there is nothing to gather; the parser must simply
    # not crash and report zero collectives
    assert st.collective_bytes >= 0


def test_dus_fusion_charged_by_update_window():
    """A scan writing one slot per step into a big carried buffer must
    be charged per-slot, not per-buffer (the in-place decode-cache
    pattern)."""
    T, N = 8, 4096

    def step(init):
        def body(buf, i):
            upd = jnp.ones((1, 16), jnp.float32) * i.astype(jnp.float32)
            return jax.lax.dynamic_update_slice(buf, upd, (i, 0)), None
        out, _ = jax.lax.scan(body, init, jnp.arange(T))
        return out

    init = jax.ShapeDtypeStruct((N, 16), jnp.float32)
    compiled = jax.jit(step, donate_argnums=(0,)).lower(init).compile()
    st = hlo_stats.analyze(compiled.as_text())
    # the buffer is N*16*4 = 256 KiB; per-step traffic must be ~the
    # 64-byte slot, so total << one full-buffer pass
    assert st.hbm_bytes < N * 16 * 4, st.hbm_bytes


def test_cell_rules_policies():
    """Sharding-policy selection: heads-shardable archs get TP
    attention; non-divisible ones fall back to CP; decode shards the
    cache seq; long-context batch-1 decode spreads the cache over
    (data, model)."""
    from repro.configs import get_config
    cfg_ok = get_config("gemma_7b")       # 16 heads -> TP
    cfg_cp = get_config("qwen25_32b")     # 40 heads -> CP fallback
    train = ShapeCell("train_4k", "train", 4096, 256)
    dec = ShapeCell("decode_32k", "decode", 32768, 128)
    long = ShapeCell("long_500k", "decode", 524288, 1)
    r1 = specs_mod.cell_rules(cfg_ok, train)
    assert r1.acts["seq"] == ()
    r2 = specs_mod.cell_rules(cfg_cp, train)
    assert r2.acts["seq"] == ("model",)
    r3 = specs_mod.cell_rules(cfg_ok, dec)
    assert r3.acts["cache_seq"] == ("model",)
    r4 = specs_mod.cell_rules(cfg_ok, long)
    assert r4.acts["cache_seq"] == ("data", "model")
